"""Pivoting between dense arrays and the relational representation.

The paper stores a matrix as the relation ``{[i, j, v]}`` (Fig. 1) with
**1-based** indices (``generate_series(1, n)`` in Listing 5); the JAX side
(:class:`repro.core.relational.RelTensor`) is 0-based.  This module is the
boundary: every matrix entering the database is pivoted to 1-based tuples,
everything read back is pivoted to a dense 0-based array.

All pivots are vectorized (``np.repeat``/``tile``/``ravel`` plus fancy
indexing) — at MNIST scale (784×256 ≈ 200k cells) the per-cell Python loop
of the original implementation dominates ingestion by >10×.  That loop is
kept as :func:`matrix_to_rows_percell`, the measured baseline of
``benchmarks/bench_mnist_db.py``.
"""
from __future__ import annotations

import numpy as np

from ..core.relational import RelTensor
from ..obs import tracer_of
from .adapter import Adapter, _check_ident
from .dialect import json_to_matrix, matrix_to_json


def _count_ingest(adapter: Adapter, a: np.ndarray) -> None:
    """Ingestion volume counters (``SQLEngine.stats`` → ``adapter``)."""
    adapter.add_counters(ingest_bytes=int(a.nbytes),
                        ingest_cells=int(a.size))


#: largest leaf (in cells) whose client-side copy is retained as the diff
#: base of the bound-parameter delta path — optimizer state and per-step
#: inputs qualify; MNIST-scale weight relations stay resident but refresh
#: via DELETE + re-insert (no DDL churn) instead of cell updates
DELTA_MAX_CELLS = 65536


def _register_matrix(adapter: Adapter, name: str, a: np.ndarray,
                     representation: str, cache: bool = True) -> None:
    """Record what the table now holds, enabling the delta path for the
    next refresh of the same leaf (small relational matrices additionally
    keep a client copy to diff against)."""
    adapter.matrix_meta[name] = (representation, a.shape)
    if (cache and representation == "relational"
            and 0 < a.size <= DELTA_MAX_CELLS):
        adapter.matrix_cache[name] = a.copy()
    else:
        adapter.matrix_cache.pop(name, None)
    # pin the caches to the table's current generation: a sibling pooled
    # connection's write bumps it, flipping adapter.cache_fresh(name) off
    adapter.matrix_gen[name] = adapter.table_gen(name)


#: column layout of every matrix table, matching the paper's Fig. 1
MATRIX_COLUMNS = (("i", "integer"), ("j", "integer"), ("v", "double precision"))

#: column layout of an array-representation matrix table: the whole matrix
#: is ONE row, column ``m`` holding the JSON array codec (paper §5)
ARRAY_COLUMNS = (("m", "text"),)

#: batched twins — a leading 0-based request index ``b``; one table holds
#: B independent per-request matrices and ONE rendered plan evaluates all
#: of them (the multi-tenant serving codec)
MATRIX_BATCH_COLUMNS = (("b", "integer"),) + MATRIX_COLUMNS
ARRAY_BATCH_COLUMNS = (("b", "integer"),) + ARRAY_COLUMNS


# ---------------------------------------------------------------------------
# dense ↔ columns / rows
# ---------------------------------------------------------------------------

def matrix_to_columns(x) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dense matrix → column vectors ``(i, j, v)`` in canonical row-major
    order, 1-based.  This is the zero-copy-ish form the adapters ingest
    (chunked ``executemany`` on sqlite, Arrow/ndarray registration on
    duckdb)."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim != 2:
        raise ValueError(f"expected a matrix, got shape {a.shape}")
    r, c = a.shape
    i = np.repeat(np.arange(1, r + 1, dtype=np.int64), c)
    j = np.tile(np.arange(1, c + 1, dtype=np.int64), r)
    return i, j, a.ravel()


def batch_to_columns(x) -> tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
    """Batched dense stack ``(B, r, c)`` → column vectors ``(b, i, j, v)``:
    ``b`` 0-based request index, ``(i, j)`` 1-based within each request —
    the batched-leaf ingestion form of the multi-tenant serving tier."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim != 3:
        raise ValueError(f"expected a (B, rows, cols) stack, got {a.shape}")
    nb, r, c = a.shape
    b = np.repeat(np.arange(nb, dtype=np.int64), r * c)
    i = np.tile(np.repeat(np.arange(1, r + 1, dtype=np.int64), c), nb)
    j = np.tile(np.arange(1, c + 1, dtype=np.int64), nb * r)
    return b, i, j, a.ravel()


def columns_to_rows(i, j, v) -> list[tuple[int, int, float]]:
    """Column vectors → ``[(i, j, v)]`` with native Python scalars.
    ``tolist()`` + ``zip`` run in C — no per-cell Python arithmetic."""
    return list(zip(i.tolist(), j.tolist(), v.tolist()))


def matrix_to_rows(x) -> list[tuple[int, int, float]]:
    """Dense matrix → canonical row-major ``[(i, j, v)]`` (1-based)."""
    return columns_to_rows(*matrix_to_columns(x))


def matrix_to_rows_percell(x) -> list[tuple[int, int, float]]:
    """The original per-cell pivot — one Python iteration (and one
    ``float()`` call) per matrix cell.  Kept only as the ingestion baseline
    for the MNIST-scale benchmark; use :func:`matrix_to_rows`."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim != 2:
        raise ValueError(f"expected a matrix, got shape {a.shape}")
    return [(i + 1, j + 1, float(a[i, j]))
            for i in range(a.shape[0]) for j in range(a.shape[1])]


def rows_to_matrix(rows, shape: tuple[int, int]) -> np.ndarray:
    """``[(i, j, v)]`` (1-based, any order, gaps → 0) → dense matrix.

    Missing cells coalesce to 0 — the outer-join semantics of Listing 5's
    one-hot construction.  One fancy-indexed assignment instead of a
    Python loop."""
    out = np.zeros(shape, dtype=np.float64)
    if not len(rows):
        return out
    arr = np.asarray(rows, dtype=np.float64)
    out[arr[:, 0].astype(np.int64) - 1, arr[:, 1].astype(np.int64) - 1] \
        = arr[:, 2]
    return out


# ---------------------------------------------------------------------------
# RelTensor ↔ rows (round-trips the JAX relational representation)
# ---------------------------------------------------------------------------

def reltensor_to_columns(rt: RelTensor
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Valid tuples only, as 1-based column vectors: padding rows
    (``i == shape[0]``) are dropped, just as the inner join drops them
    on-device."""
    i = np.asarray(rt.i, dtype=np.int64)
    j = np.asarray(rt.j, dtype=np.int64)
    v = np.asarray(rt.v, dtype=np.float64)
    keep = i < rt.shape[0]
    return i[keep] + 1, j[keep] + 1, v[keep]


def reltensor_to_rows(rt: RelTensor) -> list[tuple[int, int, float]]:
    return columns_to_rows(*reltensor_to_columns(rt))


def rows_to_reltensor(rows, shape: tuple[int, int]) -> RelTensor:
    """Rows → canonical (dense row-major) RelTensor."""
    return RelTensor.from_dense(
        np.asarray(rows_to_matrix(rows, shape), dtype=np.float32))


# ---------------------------------------------------------------------------
# adapter-level matrix tables
# ---------------------------------------------------------------------------

def write_matrix(adapter: Adapter, name: str, x, temp: bool = False) -> None:
    """CREATE + bulk-ingest the relation for ``x`` (replacing any old one).

    Ingestion auto-selects per adapter: where the runtime engine expands
    JSON in linear time (``adapter.prefers_json_ingest`` — sqlite ≥ 3.38),
    the pivot moves *into* the engine via ``json_each``
    (:func:`write_matrix_json`'s path); everywhere else — including this
    container's sqlite 3.34, whose pre-3.38 ``json_each`` is quadratic —
    the vectorized client pivot + column ingestion stays the default.
    Non-finite values always take the VALUES path (sqlite's JSON parser
    rejects NaN/Infinity tokens).

    ``temp=True`` scopes the relation to this connection (per-shard
    leaves, ``SQLEngine(temp_leaves=True)``): sibling pooled connections
    never see it and their caches are never invalidated by it."""
    a = np.asarray(x, dtype=np.float64)
    with tracer_of(adapter).span("io.write_matrix", table=name,
                                 cells=int(a.size)):
        adapter.create_table(name, MATRIX_COLUMNS, temp=temp)
        used_json = (getattr(adapter, "prefers_json_ingest", False)
                     and a.ndim == 2 and np.isfinite(a).all())
        if used_json:
            adapter.insert_matrix_json(name, a)
        else:
            adapter.insert_columns(name, matrix_to_columns(a))
        _count_ingest(adapter, a)
    if a.ndim == 2:
        # json_each values round-trip text→real (~1 ulp); the stored cells
        # may then differ from the client copy, so no diff base is kept
        _register_matrix(adapter, name, a, "relational", cache=not used_json)


def write_matrix_batch(adapter: Adapter, name: str, x,
                       temp: bool = True) -> None:
    """CREATE + ingest a batched relational leaf: ``x`` is a ``(B, r, c)``
    stack, the table ``{[b, i, j, v]}``.  Temp by default — batched
    request leaves are per-connection scratch, invisible to (and never
    invalidating) sibling pooled connections."""
    a = np.asarray(x, dtype=np.float64)
    with tracer_of(adapter).span("io.write_matrix_batch", table=name,
                                 cells=int(a.size),
                                 batch=int(a.shape[0]) if a.ndim else 0):
        adapter.create_table(name, MATRIX_BATCH_COLUMNS, temp=temp)
        adapter.insert_columns(name, batch_to_columns(a))
        _count_ingest(adapter, a)


def write_matrix_array_batch(adapter: Adapter, name: str, x,
                             temp: bool = True) -> None:
    """Batched array-representation leaf: one ``(b, m)`` row per request."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim != 3:
        raise ValueError(f"expected a (B, rows, cols) stack, got {a.shape}")
    with tracer_of(adapter).span("io.write_matrix_array_batch", table=name,
                                 cells=int(a.size), batch=int(a.shape[0])):
        adapter.create_table(name, ARRAY_BATCH_COLUMNS, temp=temp)
        adapter.bulk_insert(name, [(k, matrix_to_json(a[k]))
                                   for k in range(a.shape[0])])
        _count_ingest(adapter, a)


def write_matrix_json(adapter: Adapter, name: str, x) -> None:
    """The JSON-array ingestion path (``SQLiteAdapter.insert_matrix_json``):
    the (i, j, v) expansion happens inside the engine via ``json_each``.
    Values may differ from the source by ~1 ulp (sqlite's text→real)."""
    if not adapter.supports_json_ingest:
        raise NotImplementedError(
            f"{type(adapter).__name__} has no table-valued JSON ingestion")
    adapter.create_table(name, MATRIX_COLUMNS)
    adapter.insert_matrix_json(name, x)


def write_matrix_percell(adapter: Adapter, name: str, x) -> None:
    """The pre-vectorization ingestion path (per-cell pivot + one flat
    ``executemany``) — the benchmark baseline."""
    adapter.create_table(name, MATRIX_COLUMNS)
    adapter.bulk_insert(name, matrix_to_rows_percell(x))


def read_matrix(adapter: Adapter, name: str,
                shape: tuple[int, int]) -> np.ndarray:
    rows = adapter.execute(f"select i, j, v from {_check_ident(name)}")
    return rows_to_matrix(rows, shape)


def write_matrix_array(adapter: Adapter, name: str, x,
                       temp: bool = False) -> None:
    """CREATE + ingest ``x`` in the *array* representation: one row, one
    array-typed (JSON codec) column — the leaf layout the ``array`` dialect
    renders against (``SQLEngine(dialect="array")``).  ``temp=True`` as in
    :func:`write_matrix`."""
    a = np.asarray(x, dtype=np.float64)
    with tracer_of(adapter).span("io.write_matrix_array", table=name,
                                 cells=int(a.size)):
        adapter.create_table(name, ARRAY_COLUMNS, temp=temp)
        adapter.bulk_insert(name, [(matrix_to_json(a),)])
        _count_ingest(adapter, a)
    if a.ndim == 2:
        _register_matrix(adapter, name, a, "array", cache=False)


def update_matrix_delta(adapter: Adapter, name: str, x) -> int | None:
    """Bound-parameter in-place refresh of a RESIDENT relational matrix.

    Returns the number of value bytes actually rebound, or ``None`` when
    the relation is not resident with a matching shape (caller falls back
    to :func:`write_matrix`).  Small leaves (``DELTA_MAX_CELLS``) diff
    against the retained client copy and UPDATE only the changed cells
    through one prepared statement (``adapter.update_cells``); larger
    resident relations are rewritten in place — DELETE + re-insert keeps
    the table object, its schema and the driver's cached INSERT statement,
    instead of the DROP/CREATE churn of a full write."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim != 2 or adapter.matrix_meta.get(name) != ("relational",
                                                        a.shape):
        return None
    if not adapter.cache_fresh(name):
        # a sibling pooled connection rewrote the relation since our copy
        # was recorded — patching cells on top of ITS content would write
        # a silent mix of two matrices; drop our caches (no generation
        # bump: the resident content is valid) and force the full path
        adapter.forget(name)
        return None
    prev = adapter.matrix_cache.get(name)
    tr = tracer_of(adapter)
    if prev is not None and 0 < a.size <= DELTA_MAX_CELLS:
        # NaN compares unequal to itself, so non-finite cells always
        # re-bind — conservative and round-trip-identical to a full write
        changed = np.flatnonzero(a.ravel() != prev.ravel())
        with tr.span("io.update_matrix", table=name, mode="delta",
                     cells=int(changed.size)):
            if changed.size:
                adapter.update_cells(name, changed, a.ravel()[changed],
                                     a.shape)
        _register_matrix(adapter, name, a, "relational")
        adapter.add_counters(delta_updates=1,
                             ingest_bytes=int(changed.size) * 8,
                             ingest_cells=int(changed.size))
        return int(changed.size) * 8
    with tr.span("io.update_matrix", table=name, mode="rewrite",
                 cells=int(a.size)):
        adapter.execute(f"delete from {_check_ident(name)}")
        adapter.insert_columns(name, matrix_to_columns(a))
    _register_matrix(adapter, name, a, "relational")
    _count_ingest(adapter, a)
    return int(a.nbytes)


def update_matrix_array(adapter: Adapter, name: str, x) -> bool:
    """Single-row bound-parameter refresh of an array-representation leaf
    — ``update ... set m = ?`` against the resident row instead of
    DROP/CREATE/INSERT.  True when the in-place update applied."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim != 2 or adapter.matrix_meta.get(name) != ("array", a.shape):
        return False
    if not adapter.cache_fresh(name):
        adapter.forget(name)  # sibling write — see update_matrix_delta
        return False
    with tracer_of(adapter).span("io.update_matrix_array", table=name,
                                 cells=int(a.size)):
        adapter.execute(
            f"update {_check_ident(name)} set m = {adapter.placeholder}",
            (matrix_to_json(a),))
    adapter.bump_gen(name)  # content changed under sibling caches
    _register_matrix(adapter, name, a, "array", cache=False)
    adapter.add_counters(delta_updates=1)
    _count_ingest(adapter, a)
    return True


# ---------------------------------------------------------------------------
# cross-connection gradient shipping (the AllReduce input of db/shard.py)
# ---------------------------------------------------------------------------

#: coordinator-side gradient relation: ``r`` the multi-root tag of the
#: shard plan's result rows (1.. = the wrt weights, in order), ``s`` the
#: shard index — the SQL AllReduce groups on (r, i, j) across ``s``
SHARD_GRAD_COLUMNS = (("r", "integer"), ("s", "integer")) + MATRIX_COLUMNS

#: array-representation twin: one codec row per (weight, shard)
SHARD_GRAD_ARRAY_COLUMNS = (("r", "integer"), ("s", "integer")) + ARRAY_COLUMNS


def create_shard_grads(adapter: Adapter, name: str, representation: str,
                       temp: bool = True) -> None:
    """The coordinator's gradient landing relation (temp by default — it
    is per-coordinator scratch, rebuilt every step)."""
    cols = (SHARD_GRAD_COLUMNS if representation == "relational"
            else SHARD_GRAD_ARRAY_COLUMNS)
    adapter.create_table(name, cols, temp=temp)


def ship_grad_rows(adapter: Adapter, name: str, shard: int, rows,
                   representation: str, grad_roots_from: int = 1) -> int:
    """Import one shard's tagged multi-root result rows (the raw output of
    ``SQLEngine.evaluate_rows``) into the coordinator's gradient relation,
    stamped with the shard index — the export/import half of the SQL
    AllReduce.  Rows tagged below ``grad_roots_from`` (the loss root) are
    not gradients and are skipped.  Returns the number of rows shipped."""
    kept = [row for row in rows if row[0] >= grad_roots_from]
    n = len(kept)
    with tracer_of(adapter).span("io.ship_grads", table=name, shard=shard,
                                 rows=n):
        if not n:
            return 0
        if representation == "relational":
            arr = np.asarray(kept, dtype=np.float64)
            adapter.insert_columns(name, (
                arr[:, 0].astype(np.int64),
                np.full(n, shard, dtype=np.int64),
                arr[:, 1].astype(np.int64),
                arr[:, 2].astype(np.int64),
                arr[:, 3]))
        else:
            adapter.bulk_insert(name, [(int(r), shard, m)
                                       for r, m in kept])
        adapter.add_counters(shipped_rows=n)
    return n


def read_matrix_array(adapter: Adapter, name: str) -> np.ndarray:
    rows = adapter.execute(f"select m from {_check_ident(name)}")
    return json_to_matrix(rows[0][0])


def write_reltensor(adapter: Adapter, name: str, rt: RelTensor) -> None:
    with tracer_of(adapter).span("io.write_reltensor", table=name):
        adapter.create_table(name, MATRIX_COLUMNS)
        i, j, v = reltensor_to_columns(rt)
        adapter.insert_columns(name, (i, j, v))
        _count_ingest(adapter, v)


def read_reltensor(adapter: Adapter, name: str,
                   shape: tuple[int, int]) -> RelTensor:
    rows = adapter.execute(f"select i, j, v from {_check_ident(name)}")
    return rows_to_reltensor(rows, shape)
