"""Observability for the in-database engine: tracing, metrics, exporters.

The measurement story of the paper (§7 is entirely runtime/memory curves)
applied to our own stack: :class:`~repro.obs.tracer.Tracer` collects
nested, attributed spans from every layer of the execution path (plan
render, cache lookup, leaf ingestion, query execution, result decode,
training iterations, serving decode steps), counters/gauges ride along,
and the exporters turn the capture into a Chrome-trace/Perfetto JSON or a
``trace_spans`` relation *inside the traced database* — engine telemetry
you query with SQL, like everything else in this repo.

Beyond spans: :mod:`~repro.obs.metrics` adds log-spaced-bucket histograms
(``tracer.observe`` — p50/p95/p99 with no per-sample storage) and the
``metric_points`` time-series relation (``tracer.point`` — training loss,
gradient norm, cache hit rate, tokens/s); :mod:`~repro.obs.profiler` is
the per-IR-node profiled execution mode (``SQLEngine.profile`` — every
node its own timed temp-table step, emitted as a ``profile_nodes``
relation); :mod:`~repro.obs.regress` compares benchmark ``metrics`` blocks
against committed baselines (the CI perf gate); ``python -m
repro.obs.report`` prints all of it from a trace JSON or a traced
database.

Zero-cost by default: the active tracer is a no-op singleton until
:func:`install`/:func:`use` swaps a collecting one in (or an engine is
constructed with ``tracer=...``).

    from repro import obs
    tracer = obs.Tracer()
    with obs.use(tracer):
        eng.evaluate([root], env)           # spans collected everywhere
    obs.write_chrome_trace(tracer, "trace.json")
    obs.write_trace_spans(eng.adapter, tracer)   # → SQL-queryable relation
    obs.write_metric_points(eng.adapter, tracer)
    print(obs.stage_breakdown(tracer, root="sql.evaluate"))
    print(eng.profile([root], env).report(top=10))
"""
from .export import (STAGE_SQL, TRACE_SPAN_COLUMNS, chrome_trace,
                     stage_breakdown, summarize, write_chrome_trace,
                     write_trace_spans)
from .metrics import (METRIC_POINT_COLUMNS, METRIC_SQL, Histogram,
                      MetricPoint, percentiles_from_values,
                      write_metric_points)
from .profiler import (NODE_SQL, PROFILE_NODE_COLUMNS, NodeCost,
                       ProfileResult, profile_evaluate,
                       profile_value_and_grad, write_profile_nodes)
from .regress import (Delta, compare, delta_table, metric,
                      metrics_from_report)
from .tracer import (NOOP_SPAN, NullTracer, Span, Tracer, current, install,
                     tracer_of, use)

__all__ = [
    "Span", "Tracer", "NullTracer", "NOOP_SPAN",
    "current", "install", "use", "tracer_of",
    "chrome_trace", "write_chrome_trace", "write_trace_spans",
    "summarize", "stage_breakdown", "STAGE_SQL", "TRACE_SPAN_COLUMNS",
    "Histogram", "MetricPoint", "write_metric_points",
    "percentiles_from_values", "METRIC_SQL", "METRIC_POINT_COLUMNS",
    "NodeCost", "ProfileResult", "profile_evaluate",
    "profile_value_and_grad", "write_profile_nodes",
    "NODE_SQL", "PROFILE_NODE_COLUMNS",
    "Delta", "compare", "delta_table", "metric", "metrics_from_report",
]
