"""Public, jit-compiled entry points for the kernel package.

Every op dispatches between the Pallas kernel (TPU target; ``interpret=True``
executes the kernel body on CPU for validation) and the pure-jnp reference
path (used by the dry-run so XLA's SPMD partitioner sees plain HLO).

On real TPU hardware the ``use_pallas=True`` path compiles the Mosaic
kernels; this container is CPU-only, so tests exercise interpret mode.
"""
from __future__ import annotations

import jax

from . import ref
from .flash_attention import flash_attention as _flash_pallas
from .fused_sigmoid_matmul import fused_sigmoid_matmul as _fsm_pallas
from .moe_dispatch import moe_dispatch as _dispatch_pallas
from .onehot_embed import onehot_embed as _embed_pallas
from .relational_matmul import relational_matmul as _relmm_pallas
from .rwkv6_scan import rwkv6_scan as _rwkv6_pallas


def relational_matmul(row_ids, col_ids, vals, b, m: int, *,
                      use_pallas: bool = False, **kw) -> jax.Array:
    if use_pallas:
        return _relmm_pallas(row_ids, col_ids, vals, b, m, **kw)
    return ref.relational_matmul(row_ids, col_ids, vals, b, m)


def fused_sigmoid_matmul(x, w, *, use_pallas: bool = False, **kw) -> jax.Array:
    if use_pallas:
        return _fsm_pallas(x, w, **kw)
    return ref.fused_sigmoid_matmul(x, w)


def onehot_embed(ids, table, *, use_pallas: bool = False, **kw) -> jax.Array:
    if use_pallas:
        return _embed_pallas(ids, table, **kw)
    return ref.onehot_embed(ids, table)


def moe_dispatch(x, sort_idx, gates, *, use_pallas: bool = False, **kw
                 ) -> jax.Array:
    if use_pallas:
        return _dispatch_pallas(x, sort_idx, gates, **kw)
    return ref.moe_dispatch(x, sort_idx, gates)


def moe_combine(expert_out, row_ids, n_tokens: int) -> jax.Array:
    return ref.moe_combine(expert_out, row_ids, n_tokens)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    use_pallas: bool = False, **kw) -> jax.Array:
    if use_pallas:
        return _flash_pallas(q, k, v, causal=causal, scale=scale, **kw)
    return ref.flash_attention(q, k, v, causal=causal, scale=scale)


def rwkv6_scan(r, k, v, w, u, s0, *, use_pallas: bool = False, **kw):
    if use_pallas:
        return _rwkv6_pallas(r, k, v, w, u, s0, **kw)
    return ref.rwkv6_scan(r, k, v, w, u, s0)
