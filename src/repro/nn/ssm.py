"""Recurrent sequence mixers: RWKV-6 (Finch) and Mamba-2 (SSD).

The paper's technique (matmul-as-join) does not apply to these data-dependent
recurrences (DESIGN.md §Arch-applicability) — they are implemented as
first-class JAX layers so the assigned ``rwkv6-7b`` and ``zamba2-2.7b``
architectures run without it.

RWKV-6 time-mix: per-head matrix state S (N×N), *vector*-valued
data-dependent decay w_t (the Finch contribution, arXiv:2404.05892):

    o_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Executed as a ``lax.scan`` over time (vectorised over batch × heads). A
chunkwise-parallel form exists (GLA-style) but its factorised decay
``exp(−a_i)`` overflows f32 for fast-decaying channels; the scan is exact.
See EXPERIMENTS.md §Perf for the memory/FLOP trade discussion.

Mamba-2 SSD: *scalar*-per-head decay makes the chunked form stable, so we
implement the block-decomposition of the SSD paper (arXiv:2405.21060):
diagonal blocks use the masked-decay matmul, off-diagonal blocks flow through
a chunk-state recurrence. A naive scan oracle validates it in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# RWKV-6 time mix
# ---------------------------------------------------------------------------

def rwkv6_init(key, d: int, n_heads: int, lora_rank: int = 64):
    n = d // n_heads
    ks = jax.random.split(key, 10)
    return {
        "mu": {nm: jnp.full((d,), 0.5, jnp.float32)
               for nm in ("r", "k", "v", "w", "g")},
        "wr": dense_init(ks[0], (d, d)), "wk": dense_init(ks[1], (d, d)),
        "wv": dense_init(ks[2], (d, d)), "wg": dense_init(ks[3], (d, d)),
        "wo": dense_init(ks[4], (d, d)),
        "w0": jnp.full((d,), -2.0, jnp.float32),     # base decay ≈ exp(-e^-2)
        "w_lora_a": dense_init(ks[5], (d, lora_rank)),
        "w_lora_b": dense_init(ks[6], (lora_rank, d), scale=1e-2),
        "u": dense_init(ks[7], (n_heads, n), scale=0.5),
        "ln_x": {"w": jnp.ones((d,), jnp.float32),
                 "b": jnp.zeros((d,), jnp.float32)},
    }


def _token_shift(x, x_prev):
    """x_{t-1} stream; ``x_prev`` (B, 1, d) is the carry entering this call."""
    return jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)


def _rwkv6_projections(p, x, x_prev, n_heads: int):
    b, s, d = x.shape
    n = d // n_heads
    xs = _token_shift(x, x_prev)
    mix = {nm: x + (xs - x) * p["mu"][nm].astype(x.dtype)
           for nm in ("r", "k", "v", "w", "g")}
    r = jnp.dot(mix["r"], p["wr"].astype(x.dtype))
    k = jnp.dot(mix["k"], p["wk"].astype(x.dtype))
    v = jnp.dot(mix["v"], p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.dot(mix["g"], p["wg"].astype(x.dtype)))
    # Finch: data-dependent vector decay via LoRA
    lora = jnp.dot(jnp.tanh(jnp.dot(mix["w"].astype(jnp.float32),
                                    p["w_lora_a"])), p["w_lora_b"])
    w = jnp.exp(-jnp.exp((p["w0"] + lora).astype(jnp.float32)))  # (B,S,d)
    hd = lambda t: t.reshape(b, s, n_heads, n)
    return hd(r), hd(k), hd(v), g, hd(w)


def rwkv6_time_mix(p, x, n_heads: int, state=None):
    """x: (B, S, d). state: (x_prev (B,1,d), S (B,H,N,N)) or None.
    Returns (out (B,S,d), new_state)."""
    b, s, d = x.shape
    n = d // n_heads
    if state is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
        s0 = jnp.zeros((b, n_heads, n, n), jnp.float32)
    else:
        x_prev, s0 = state
    r, k, v, g, w = _rwkv6_projections(p, x, x_prev, n_heads)
    u = p["u"]                                           # (H, N)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                         # (B,H,N) each
        kv = k_t[..., :, None] * v_t[..., None, :]       # (B,H,N,N)
        out_t = jnp.einsum("bhi,bhij->bhj",
                           r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out_t

    seq = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
           k.transpose(1, 0, 2, 3).astype(jnp.float32),
           v.transpose(1, 0, 2, 3).astype(jnp.float32),
           w.transpose(1, 0, 2, 3))
    s_fin, outs = jax.lax.scan(step, s0, seq)
    o = outs.transpose(1, 0, 2, 3).reshape(b, s, d)      # (B,S,d)
    # per-head group norm (ln over each head's channels)
    o = o.reshape(b, s, n_heads, n)
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = ((o - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d)
    o = o * p["ln_x"]["w"] + p["ln_x"]["b"]
    o = (o.astype(x.dtype) * g)
    out = jnp.dot(o, p["wo"].astype(x.dtype))
    return out, (x[:, -1:].astype(jnp.float32), s_fin)


def rwkv6_channel_mix_init(key, d: int, ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "wk": dense_init(k1, (d, ff)), "wv": dense_init(k2, (ff, d)),
            "wr": dense_init(k3, (d, d))}


def rwkv6_channel_mix(p, x, state=None):
    b, s, d = x.shape
    x_prev = jnp.zeros((b, 1, d), x.dtype) if state is None else state
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p["mu_k"].astype(x.dtype)
    xr = x + (xs - x) * p["mu_r"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(jnp.dot(xk, p["wk"].astype(x.dtype))))
    r = jax.nn.sigmoid(jnp.dot(xr, p["wr"].astype(x.dtype)))
    return r * jnp.dot(h, p["wv"].astype(x.dtype)), x[:, -1:].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) — chunked block decomposition
# ---------------------------------------------------------------------------

def mamba2_init(key, d: int, n_heads: int, d_state: int, d_conv: int = 4,
                expand: int = 2):
    d_inner = expand * d
    head_p = d_inner // n_heads
    ks = jax.random.split(key, 4)
    return {
        # in_proj emits z (gate), x, B, C, dt
        "in_proj": dense_init(ks[0], (d, 2 * d_inner + 2 * d_state + n_heads)),
        "conv_w": dense_init(ks[1], (d_conv, d_inner + 2 * d_state),
                             scale=0.5),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner),
        "out_proj": dense_init(ks[2], (d_inner, d)),
    }


def _segsum(a):
    """exp-able segment sums: out[..., t, s] = Σ_{r=s+1..t} a[..., r] (t ≥ s)."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, a, b_in, c_in, chunk: int = 64, h0=None,
                compute_dtype=jnp.float32):
    """Mamba-2 SSD. x: (B,S,H,P), a: (B,S,H) log-decay (≤0),
    b_in/c_in: (B,S,N). Returns (y (B,S,H,P), h_fin (B,H,N,P)).
    ``compute_dtype=bf16`` keeps the big chunk tensors low-precision
    (decay cumsums stay f32) — §Perf memory lever."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    assert s % chunk == 0 or s == 1
    if s == 1:  # decode step: plain recurrence
        h_prev = jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None else h0
        da = jnp.exp(a[:, 0])                                     # (B,H)
        hb = h_prev * da[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_in[:, 0].astype(jnp.float32),
            x[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhnp->bhp", c_in[:, 0].astype(jnp.float32), hb)
        return y[:, None].astype(x.dtype), hb
    nc = s // chunk
    xs = x.reshape(bsz, nc, chunk, h, p).astype(compute_dtype)
    As = a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)       # (B,H,nc,C)
    Bs = b_in.reshape(bsz, nc, chunk, n).astype(compute_dtype)
    Cs = c_in.reshape(bsz, nc, chunk, n).astype(compute_dtype)
    A_cum = jnp.cumsum(As, axis=-1)                               # (B,H,nc,C)
    # 1. diagonal blocks
    L = jnp.exp(_segsum(As)).astype(compute_dtype)                # (B,H,nc,C,C)
    y_diag = jnp.einsum("bzln,bzsn,bhzls,bzshp->bzlhp",
                        Cs, Bs, L, xs,
                        preferred_element_type=jnp.float32)
    # 2. chunk states (decay to chunk end)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)         .astype(compute_dtype)                                    # (B,H,nc,C)
    states = jnp.einsum("bzcn,bhzc,bzchp->bzhnp", Bs, decay_states, xs,
                        preferred_element_type=jnp.float32)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(A_cum[..., -1])                         # (B,H,nc)

    def scan_fn(hprev, inp):
        st, dk = inp                                              # (B,H,N,P),(B,H)
        hnew = hprev * dk[..., None, None] + st
        return hnew, hprev

    h_init = jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None else h0
    h_fin, h_prevs = jax.lax.scan(
        scan_fn, h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                    # (B,nc,H,N,P)
    # 4. off-diagonal contribution (state entering each chunk)
    state_decay = jnp.exp(A_cum).astype(compute_dtype)            # (B,H,nc,C)
    y_off = jnp.einsum("bzln,bhzl,bzhnp->bzlhp", Cs, state_decay,
                       h_prevs.astype(compute_dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), h_fin


def ssd_scan(x, a, b_in, c_in, chunk: int = 64, h0=None,
             compute_dtype=jnp.float32):
    """ssd_chunked with one ``lax.scan`` over chunks: identical math, but
    the per-chunk decay matrix L (B,H,C,C) and states exist for ONE chunk
    at a time — the memory model for the dry-run (the parallel form is the
    FLOP-accounting twin). Tested equal to ssd_chunked/ssd_naive."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    if s == 1 or s % chunk:
        return ssd_chunked(x, a, b_in, c_in, chunk=chunk, h0=h0,
                           compute_dtype=compute_dtype)
    nc = s // chunk
    xs = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 2, 3, 4) \
        .astype(jnp.float32)
    As = a.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)  # (nc,B,H,C)
    Bs = b_in.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3) \
        .astype(jnp.float32)
    Cs = c_in.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3) \
        .astype(jnp.float32)

    def body(hprev, inp):
        xc, ac, bc, cc = inp                       # (B,C,H,P),(B,H,C),...
        a_cum = jnp.cumsum(ac, axis=-1)            # (B,H,C)
        L = jnp.exp(_segsum(ac))                   # (B,H,C,C)
        y_diag = jnp.einsum("bln,bsn,bhls,bshp->blhp", cc, bc, L, xc)
        y_off = jnp.einsum("bln,bhl,bhnp->blhp", cc, jnp.exp(a_cum),
                           hprev)
        decay_states = jnp.exp(a_cum[..., -1:] - a_cum)
        st = jnp.einsum("bcn,bhc,bchp->bhnp", bc, decay_states, xc)
        hnew = hprev * jnp.exp(a_cum[..., -1])[..., None, None] + st
        return hnew, y_diag + y_off

    h_init = jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None else h0
    h_fin, ys = jax.lax.scan(body, h_init, (xs, As, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y.astype(x.dtype), h_fin


def ssd_naive(x, a, b_in, c_in, h0=None):
    """Step-by-step oracle for ssd_chunked."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    hst = jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None else h0
    ys = []
    for t in range(s):
        da = jnp.exp(a[:, t])
        hst = hst * da[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_in[:, t].astype(jnp.float32),
            x[:, t].astype(jnp.float32))
        ys.append(jnp.einsum("bn,bhnp->bhp",
                             c_in[:, t].astype(jnp.float32), hst))
    return jnp.stack(ys, axis=1).astype(x.dtype), hst


def mamba2_mixer(p, xin, dims: tuple[int, int, int, int], state=None,
                 chunk: int = 64, ssd_impl: str = "parallel",
                 compute_dtype=jnp.float32):
    """Full Mamba-2 block mixer. xin: (B,S,d); dims (static) =
    (d_inner, head_dim, d_state, d_conv).
    state: (conv_state (B, d_conv-1, d_inner+2N), h (B,H,N,P)) or None."""
    d_inner, head_p, n, d_conv = dims
    b, s, _ = xin.shape
    n_heads = d_inner // head_p
    zxbcdt = jnp.dot(xin, p["in_proj"].astype(xin.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    # causal depthwise conv over (x, B, C)
    if state is None:
        conv_in = jnp.pad(xbc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    else:
        conv_in = jnp.concatenate([state[0].astype(xbc.dtype), xbc], axis=1)
    wconv = p["conv_w"].astype(xbc.dtype)
    xbc_c = sum(conv_in[:, i:i + s] * wconv[i][None, None]
                for i in range(d_conv))
    xbc_c = jax.nn.silu(xbc_c)
    xpart, b_in, c_in = jnp.split(xbc_c, [d_inner, d_inner + n], axis=-1)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])[None, None] * dt_f                    # log decay
    xh = (xpart.reshape(b, s, n_heads, head_p)
          * dt_f[..., None].astype(xpart.dtype))
    h0 = None if state is None else state[1]
    ssd = ssd_scan if ssd_impl == "scan" else ssd_chunked
    y, h_fin = ssd(xh, a, b_in, c_in, chunk=min(chunk, s), h0=h0,
                   compute_dtype=compute_dtype)
    y = y + p["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = jnp.dot(y, p["out_proj"].astype(xin.dtype))
    new_conv = conv_in[:, -(d_conv - 1):] if d_conv > 1 else conv_in[:, :0]
    return out, (new_conv.astype(jnp.float32), h_fin)
