"""DAG-zoo-in-SQL smoke benchmark: MoE dispatch and RWKV scan, SQL vs jax.

Times the two §8-outlook transpilations (``repro.db.zoo``) against their
jax references and checks the ≤1e-4 differential contract on the way:

* **MoE** — the fully-in-DB gated layer (route → per-expert SwiGLU →
  combine) vs ``zoo.moe_ffn_ref`` (jnp, identical semantics), plus the
  relational dispatch/combine pair vs ``kernels/ref.moe_dispatch`` /
  ``moe_combine``;
* **RWKV** — the time-mix recurrence (ONE recursive CTE over the
  flattened N² state) vs ``kernels/ref.rwkv6_scan``, and the token-shift
  channel mix vs its numpy oracle.

Emits ``BENCH_zoo_db.json``.  CI runs it on sqlite (tier-1 smoke) and on
duckdb (extras job) and uploads the artifact.

Run:  PYTHONPATH=src python benchmarks/bench_zoo_db.py
CI smoke:  … bench_zoo_db.py --tokens 8 --seq 6
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

import jax
import jax.numpy as jnp

try:
    from common import timeit            # script mode (CI invocation)
except ImportError:  # pragma: no cover - package mode
    from .common import timeit
from repro import obs
from repro.obs import regress
from repro.db import HAVE_DUCKDB, zoo
from repro.db.sql_engine import SQLEngine
from repro.kernels import ref

TOL = 1e-4


def wall(fn, iters=3):
    """Shared warmup+median timing (benchmarks/common.py)."""
    return timeit(fn, iters=iters)


def bench_moe(args, backend: str) -> dict:
    cfg = zoo.MoESQLConfig(n_tokens=args.tokens, d_model=args.d_model,
                           n_experts=args.experts, top_k=args.top_k,
                           d_ff=args.d_ff)
    params = zoo.init_moe_params(cfg)
    rng = np.random.RandomState(0)
    x = rng.randn(cfg.n_tokens, cfg.d_model).astype(np.float32)

    out_ref = zoo.moe_ffn_ref(cfg, params, x)
    t_jax = wall(lambda: zoo.moe_ffn_ref(cfg, params, x), args.timing_iters)

    eng = SQLEngine(backend=backend)
    graph = zoo.moe_ffn_graph(cfg)
    env = zoo.moe_env(cfg, params, x)
    fn = eng.eval_fn([graph.out])
    out_db, = fn(env)
    t_sql = wall(lambda: fn(env), args.timing_iters)

    # relational dispatch/combine pair vs the kernel references
    t, k = cfg.n_tokens, cfg.top_k
    tok = np.tile(np.arange(t, dtype=np.int32), (k, 1)).T.reshape(-1)
    gates = rng.rand(t * k).astype(np.float32)
    disp, _, _, _ = zoo.moe_dispatch_graph(t, cfg.d_model, t * k)
    denv = {"x": x, "slot_token": tok.reshape(-1, 1).astype(np.float64),
            "slot_gate": gates.reshape(-1, 1).astype(np.float64)}
    dfn = eng.eval_fn([disp])
    disp_db, = dfn(denv)
    disp_ref = np.asarray(ref.moe_dispatch(jnp.asarray(x), jnp.asarray(tok),
                                           jnp.asarray(gates)))
    t_disp_sql = wall(lambda: dfn(denv), args.timing_iters)
    t_disp_jax = wall(lambda: jax.block_until_ready(
        ref.moe_dispatch(jnp.asarray(x), jnp.asarray(tok),
                         jnp.asarray(gates))), args.timing_iters)
    eng.close()

    err_layer = float(np.abs(out_db - out_ref).max())
    err_disp = float(np.abs(disp_db - disp_ref).max())
    return {
        "config": dataclasses.asdict(cfg),
        "layer_jax_s": t_jax, "layer_sql_s": t_sql,
        "dispatch_jax_s": t_disp_jax, "dispatch_sql_s": t_disp_sql,
        "layer_max_err": err_layer, "dispatch_max_err": err_disp,
        "within_tol": bool(err_layer < TOL and err_disp < TOL),
    }


def bench_rwkv(args, backend: str) -> dict:
    s, n = args.seq, args.heads_n
    rng = np.random.RandomState(1)
    r, k, v = [rng.randn(s, n).astype(np.float32) * 0.5 for _ in range(3)]
    w = (rng.rand(s, n) * 0.5 + 0.3).astype(np.float32)
    u = (rng.randn(n) * 0.5).astype(np.float32)
    s0 = (rng.randn(n, n) * 0.3).astype(np.float32)

    def jref():
        return jax.block_until_ready(ref.rwkv6_scan(
            jnp.asarray(r[None]), jnp.asarray(k[None]), jnp.asarray(v[None]),
            jnp.asarray(w[None]), jnp.asarray(u[None]),
            jnp.asarray(s0[None])))

    o_ref, sfin_ref = jref()
    t_jax = wall(jref, args.timing_iters)

    eng = SQLEngine(backend=backend)
    graph = zoo.rwkv6_time_mix_graph(s, n)
    env = zoo.rwkv6_env(r, k, v, w, u, s0)
    fn = eng.eval_fn([graph.o, graph.state])
    o_db, states = fn(env)
    t_sql = wall(lambda: fn(env), args.timing_iters)

    # channel mix
    d, f = n, 2 * n
    x = rng.randn(s, d).astype(np.float32)
    mu_k, mu_r = rng.rand(d), rng.rand(d)
    wk, wv_, wr = (rng.randn(d, f) * 0.3, rng.randn(f, d) * 0.3,
                   rng.randn(d, d) * 0.3)
    cm_db = zoo.run_channel_mix_in_db(x, mu_k, mu_r, wk, wv_, wr,
                                      engine=eng)
    cm_ref = zoo.rwkv_channel_mix_ref(x, mu_k, mu_r, wk, wv_, wr)
    eng.close()

    err_o = float(np.abs(np.asarray(o_ref[0]) - o_db).max())
    err_s = float(np.abs(np.asarray(sfin_ref[0]).reshape(-1)
                         - states[-1]).max())
    err_cm = float(np.abs(cm_db - cm_ref).max())
    return {
        "config": {"seq": s, "n": n},
        "time_mix_jax_s": t_jax, "time_mix_sql_s": t_sql,
        "o_max_err": err_o, "state_max_err": err_s,
        "channel_mix_max_err": err_cm,
        "within_tol": bool(max(err_o, err_s, err_cm) < TOL),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=8)
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--d-ff", type=int, default=16)
    ap.add_argument("--seq", type=int, default=12)
    ap.add_argument("--heads-n", type=int, default=4,
                    help="head dim N (state is N^2 columns)")
    ap.add_argument("--timing-iters", type=int, default=3)
    ap.add_argument("--backend", default="sqlite",
                    choices=["sqlite", "duckdb", "auto"])
    ap.add_argument("--out", default="BENCH_zoo_db.json")
    args = ap.parse_args()
    backend = ("duckdb" if HAVE_DUCKDB else "sqlite") \
        if args.backend == "auto" else args.backend

    print(f"== DAG-zoo-in-SQL smoke, backend={backend} ==")
    tracer = obs.Tracer()
    with obs.use(tracer):
        moe = bench_moe(args, backend)
        print(f"moe layer: jax {moe['layer_jax_s']*1e3:8.1f} ms | sql "
              f"{moe['layer_sql_s']*1e3:8.1f} ms | max err "
              f"{moe['layer_max_err']:.2e}", flush=True)
        rwkv = bench_rwkv(args, backend)
        print(f"rwkv scan: jax {rwkv['time_mix_jax_s']*1e3:8.1f} ms | sql "
              f"{rwkv['time_mix_sql_s']*1e3:8.1f} ms | max err "
              f"{rwkv['o_max_err']:.2e}", flush=True)
    trace_path = obs.write_chrome_trace(
        tracer, args.out.rsplit(".", 1)[0] + ".trace.json")
    print(f"perfetto trace -> {trace_path}", flush=True)

    report = {"backend": backend, "have_duckdb": HAVE_DUCKDB,
              "moe": moe, "rwkv": rwkv,
              "trace": {"stage_totals": obs.summarize(tracer, top=12),
                        "zoo_layers": obs.stage_breakdown(tracer)},
              "metrics": {
                  "moe.layer_sql_s": regress.metric(moe["layer_sql_s"]),
                  "rwkv.time_mix_sql_s":
                      regress.metric(rwkv["time_mix_sql_s"]),
              },
              "checks": {"moe_within_1e-4": moe["within_tol"],
                         "rwkv_within_1e-4": rwkv["within_tol"]}}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}\nchecks: {report['checks']}")
    return 0 if all(report["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
