with const_c0(i, j, v) as (
  select a.i, b.j, 1.0 as v
  from (select generate_series as i from generate_series(1,3)) a,
       (select generate_series as j from generate_series(1,2)) b
)
select * from const_c0 order by i, j;
