"""Multi-tenant in-database serving benchmark (one plan, B requests).

PR 9 folds a ``b`` request-index column through the rendered SQL so ONE
cached plan evaluates B independent requests in a single query, and puts
a micro-batching :class:`repro.serving.db_serve.SQLBatchServer` (request
queue + connection pool) in front of it.  This benchmark measures what
that buys and emits ``BENCH_serving_db.json``.

The served model is a top-k-gated MLP forward pass: both weight matrices
are softmax-normalised and top-k sparsified *in the DAG* before the
per-request matmuls.  That preprocessing depends only on the shared
weights, so the batched renderer leaves it unbatched — computed **once
per group** — while the sequential baseline recomputes it per request.
This is the shape of workload the batch column is for: per-request work
is a thin slice, per-plan work amortises.

* **batched sweep** — warm per-group latency and requests/s of
  ``SQLEngine.evaluate_batched`` at tenant counts 1 → 64, against the
  sequential baseline (B repeated ``evaluate`` calls on the same warm
  engine).  The headline acceptance number: batched throughput at B=8
  must be ≥ 3× the B=1 sequential baseline.
* **server** — end-to-end client-observed request latency (p50/p95)
  and throughput through ``SQLBatchServer``: concurrent client threads
  submit futures, the dispatcher gathers arrivals for ``window_ms`` and
  rides them through one batched query.

Run:  PYTHONPATH=src python benchmarks/bench_serving_db.py
CI smoke:  … bench_serving_db.py --counts 1,2,8 --requests 24
           --timing-iters 2 --min-speedup 2.0
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.obs import regress
from repro.core import expr as E
from repro.db import HAVE_DUCKDB
from repro.db.plan_cache import PlanCache
from repro.db.sql_engine import SQLEngine
from repro.serving.db_serve import SQLBatchServer


def wall(fn, iters=3, warmup=True):
    if warmup:
        fn()
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def make_workload(args):
    """The served DAG: top-k-gated MLP forward.  ``img`` varies per
    request; the gated weights (softmax → top-k mask → hadamard, twice)
    are shared subgraphs the batched plan computes once per group."""
    img = E.var("img", (args.rows, args.features))
    w_xh = E.var("w_xh", (args.features, args.hidden))
    w_ho = E.var("w_ho", (args.hidden, args.classes))
    g_xh = E.softmax(w_xh)
    w_xh_eff = E.hadamard(g_xh, E.argtopk(g_xh, args.topk))
    g_ho = E.softmax(w_ho)
    w_ho_eff = E.hadamard(g_ho, E.argtopk(g_ho, args.topk))
    a_xh = E.sigmoid(E.matmul(img, w_xh_eff))
    a_ho = E.sigmoid(E.matmul(a_xh, w_ho_eff, name="a_ho"))

    rng = np.random.RandomState(0)
    shared = {"w_xh": rng.randn(args.features, args.hidden),
              "w_ho": rng.randn(args.hidden, args.classes)}

    def request(k):
        return rng.rand(args.rows, args.features)

    return [a_ho], shared, request


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------

def bench_batched_sweep(roots, shared, request, counts, backend: str,
                        timing_iters: int) -> list[dict]:
    """Warm batched group evaluation vs the same B requests sequentially,
    on one engine (plan cached after the first render — the sweep shares
    ONE rendered plan across every batch size)."""
    out = []
    with SQLEngine(backend=backend, plan_cache_=PlanCache(path=None)) as eng:
        for nb in counts:
            reqs = [request(k) for k in range(nb)]
            batch_env = {"img": np.stack(reqs)}

            def batched():
                eng.evaluate_batched(roots, shared, batch_env)

            def sequential():
                for r in reqs:
                    eng.evaluate(roots, {**shared, "img": r})

            t_batch = wall(batched, timing_iters)
            t_seq = wall(sequential, timing_iters)
            out.append({
                "batch": nb,
                "batched_group_s": t_batch,
                "batched_rps": nb / t_batch,
                "sequential_s": t_seq,
                "sequential_rps": nb / t_seq,
                "speedup": t_seq / t_batch,
            })
        misses = eng.stats["cache_misses"]
    # one batched plan + one unbatched plan rendered across the whole
    # sweep — every B rides the same cached SQL
    assert misses <= 2, misses
    return out


def bench_server(roots, shared, request, args, backend: str) -> dict:
    """Client-observed latency through the micro-batching server: N client
    threads each submit a burst of requests and wait on the futures."""
    n_req = args.requests
    n_clients = min(args.clients, n_req)
    lat_ms = [0.0] * n_req
    reqs = [request(k) for k in range(n_req)]

    with SQLBatchServer(roots, ["img"], shared, backend=backend,
                        pool_size=args.pool_size,
                        window_ms=args.window_ms,
                        max_batch=args.max_batch,
                        plan_cache_=PlanCache(path=None)) as srv:
        out0 = srv({"img": reqs[0]})       # warm: render + ingest once
        assert out0[0].shape == (args.rows, args.classes)

        def client(idx):
            for k in range(idx, n_req, n_clients):
                t0 = time.perf_counter()
                srv({"img": reqs[k]})
                lat_ms[k] = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        ts = [threading.Thread(target=client, args=(i,))
              for i in range(n_clients)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        total_s = time.perf_counter() - t0

    arr = np.asarray(lat_ms)
    return {
        "requests": n_req,
        "clients": n_clients,
        "pool_size": args.pool_size,
        "window_ms": args.window_ms,
        "max_batch": args.max_batch,
        "total_s": total_s,
        "rps": n_req / total_s,
        "p50_ms": float(np.percentile(arr, 50)),
        "p95_ms": float(np.percentile(arr, 95)),
        "max_ms": float(arr.max()),
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(args) -> dict:
    backend = ("duckdb" if HAVE_DUCKDB else "sqlite") \
        if args.backend == "auto" else args.backend
    roots, shared, request = make_workload(args)
    counts = [int(c) for c in args.counts.split(",") if c]

    print(f"== in-DB serving benchmark: gated MLP {args.rows}x"
          f"{args.features} -> {args.hidden} -> {args.classes} "
          f"(top-{args.topk}) per request, backend={backend} ==")

    sweep = bench_batched_sweep(roots, shared, request, counts, backend,
                                args.timing_iters)
    for row in sweep:
        print(f"B={row['batch']:3d}: batched {row['batched_group_s']*1e3:7.1f}"
              f" ms/group ({row['batched_rps']:7.1f} req/s)  sequential "
              f"{row['sequential_s']*1e3:7.1f} ms ({row['sequential_rps']:6.1f}"
              f" req/s)  {row['speedup']:5.2f}x", flush=True)

    server = bench_server(roots, shared, request, args, backend)
    print(f"server[{server['clients']} clients, pool {server['pool_size']}, "
          f"window {server['window_ms']}ms]: {server['requests']} requests in "
          f"{server['total_s']*1e3:.0f} ms ({server['rps']:.1f} req/s), "
          f"p50 {server['p50_ms']:.1f} ms, p95 {server['p95_ms']:.1f} ms",
          flush=True)

    by_b = {row["batch"]: row for row in sweep}
    b1 = by_b.get(1) or sweep[0]
    b8 = by_b.get(8) or sweep[-1]
    report = {
        "config": {"rows": args.rows, "features": args.features,
                   "hidden": args.hidden, "classes": args.classes,
                   "topk": args.topk, "backend": backend, "counts": counts,
                   "min_speedup": args.min_speedup,
                   "have_duckdb": HAVE_DUCKDB},
        "batched_sweep": sweep,
        "server": server,
        "metrics": {
            "serving.batched_rps_b8":
                regress.metric(b8["batched_rps"], "req/s", "higher"),
            "serving.batched_speedup_b8":
                regress.metric(b8["speedup"], "x", "higher"),
            "serving.sequential_rps_b1":
                regress.metric(b1["sequential_rps"], "req/s", "higher"),
            # queueing latency under concurrency is scheduler-noisy —
            # widen the band beyond the gate's default 1.5x
            "serving.server_p50_ms":
                regress.metric(server["p50_ms"], "ms", tolerance=3.0),
            "serving.server_p95_ms":
                regress.metric(server["p95_ms"], "ms", tolerance=3.0),
            "serving.server_rps":
                regress.metric(server["rps"], "req/s", "higher"),
        },
        "checks": {
            # acceptance bar: one batched query at B=8 serves ≥ 3× the
            # request rate of the sequential one-query-per-request loop
            # (CI smoke relaxes the factor for runner noise, it does not
            # change the workload)
            "batched_b8_ge_min_x_sequential_b1":
                b8["batched_rps"] >= args.min_speedup * b1["sequential_rps"],
            "batched_beats_sequential_at_8":
                b8["speedup"] > 1.0,
            "server_completed_all_requests":
                server["requests"] == args.requests,
        },
    }
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=1,
                    help="input tuples per request")
    ap.add_argument("--features", type=int, default=24)
    ap.add_argument("--hidden", type=int, default=24)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--topk", type=int, default=4,
                    help="experts kept per row in the gating masks")
    ap.add_argument("--counts", default="1,2,4,8,16,32,64",
                    help="comma-separated tenant counts for the batched "
                         "sweep")
    ap.add_argument("--timing-iters", type=int, default=3)
    ap.add_argument("--requests", type=int, default=64,
                    help="total requests through the server section")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--pool-size", type=int, default=2)
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="required batched-B=8 over sequential-B=1 "
                         "throughput factor")
    ap.add_argument("--backend", default="sqlite",
                    choices=["sqlite", "duckdb", "auto"])
    ap.add_argument("--out", default="BENCH_serving_db.json")
    args = ap.parse_args()

    report = run(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"\nwrote {args.out}")
    ok = all(report["checks"].values())
    print("checks:", report["checks"])
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
