"""Observability for the in-database engine: tracing, metrics, exporters.

The measurement story of the paper (§7 is entirely runtime/memory curves)
applied to our own stack: :class:`~repro.obs.tracer.Tracer` collects
nested, attributed spans from every layer of the execution path (plan
render, cache lookup, leaf ingestion, query execution, result decode,
training iterations, serving decode steps), counters/gauges ride along,
and the exporters turn the capture into a Chrome-trace/Perfetto JSON or a
``trace_spans`` relation *inside the traced database* — engine telemetry
you query with SQL, like everything else in this repo.

Zero-cost by default: the active tracer is a no-op singleton until
:func:`install`/:func:`use` swaps a collecting one in (or an engine is
constructed with ``tracer=...``).

    from repro import obs
    tracer = obs.Tracer()
    with obs.use(tracer):
        eng.evaluate([root], env)           # spans collected everywhere
    obs.write_chrome_trace(tracer, "trace.json")
    obs.write_trace_spans(eng.adapter, tracer)   # → SQL-queryable relation
    print(obs.stage_breakdown(tracer, root="sql.evaluate"))
"""
from .export import (STAGE_SQL, TRACE_SPAN_COLUMNS, chrome_trace,
                     stage_breakdown, summarize, write_chrome_trace,
                     write_trace_spans)
from .tracer import (NOOP_SPAN, NullTracer, Span, Tracer, current, install,
                     tracer_of, use)

__all__ = [
    "Span", "Tracer", "NullTracer", "NOOP_SPAN",
    "current", "install", "use", "tracer_of",
    "chrome_trace", "write_chrome_trace", "write_trace_spans",
    "summarize", "stage_breakdown", "STAGE_SQL", "TRACE_SPAN_COLUMNS",
]
