"""One contract, every backend: the Adapter behaviours the engine relies on.

Parametrized over every backend the environment can actually open:
``sqlite`` always; ``duckdb`` when the package is importable; ``postgres``
when ``psycopg2`` is importable AND ``REPRO_PG_DSN`` points at a server
(the CI ``postgres-extras`` job).  The same assertions run everywhere —
param-style round-trips, temp-table shadowing, concurrent ``executemany``,
the shared generation registry — so a new backend is held to the exact
semantics ``SQLEngine`` / ``relation_io`` / ``db.shard`` assume.
"""
from __future__ import annotations

import os
import threading

import numpy as np
import pytest

from repro.core import autodiff, nn2sql
from repro.db import HAVE_DUCKDB, ConnectionPool, connect, relation_io
from repro.db.adapters import HAVE_PSYCOPG2, PG_DSN_ENV
from repro.db.sql_engine import SQLEngine

RNG = np.random.RandomState(7)

BACKENDS = ["sqlite"]
if HAVE_DUCKDB:  # pragma: no cover - only with the [db] extra
    BACKENDS.append("duckdb")
if HAVE_PSYCOPG2 and os.environ.get(PG_DSN_ENV):  # pragma: no cover - CI
    BACKENDS.append("postgres")


@pytest.fixture(params=BACKENDS)
def backend(request):
    return request.param


@pytest.fixture
def db_path(backend, tmp_path):
    """A path every pooled connection of the backend shares: a file for
    the embedded engines, the DSN default for postgres."""
    if backend == "sqlite":
        return str(tmp_path / "contract.sqlite")
    if backend == "duckdb":  # pragma: no cover - only with the [db] extra
        return str(tmp_path / "contract.duckdb")
    return ":memory:"  # postgres: resolves to REPRO_PG_DSN


@pytest.fixture
def adapter(backend, db_path):
    ad = connect(backend, db_path)
    yield ad
    ad.close()


# ---------------------------------------------------------------------------
# param style
# ---------------------------------------------------------------------------

class TestParamStyle:
    def test_flags_are_coherent(self, adapter):
        assert adapter.paramstyle in ("qmark", "format")
        expected = "?" if adapter.paramstyle == "qmark" else "%s"
        assert adapter.placeholder == expected
        assert adapter.supports_temp_tables is True
        assert isinstance(adapter.supports_python_udfs, bool)

    def test_bound_params_round_trip(self, adapter):
        ph = adapter.placeholder
        adapter.create_table("ct_kv", [("k", "integer"),
                                       ("v", "double precision"),
                                       ("s", "text")])
        adapter.bulk_insert("ct_kv", [(1, 0.5, "a"), (2, -3.25, "b%c"),
                                      (3, 2.0 ** -40, "100%")])
        rows = adapter.execute(
            f"select v, s from ct_kv where k = {ph}", (2,))
        assert rows == [(-3.25, "b%c")]
        rows = adapter.execute(
            f"select k from ct_kv where v > {ph} and v < {ph}",
            (0.0, 1.0))
        assert sorted(r[0] for r in rows) == [1, 3]

    def test_literal_percent_without_params(self, adapter):
        """Rendered plans legitimately contain ``%`` (modulo arithmetic);
        a format-style driver must not try to interpolate it when no
        parameters are bound."""
        rows = adapter.execute("select (7 % 3) + 0")
        assert int(rows[0][0]) == 1

    def test_executemany_binds_per_row(self, adapter):
        ph = adapter.placeholder
        adapter.create_table("ct_many", [("i", "integer"),
                                         ("v", "double precision")])
        before = adapter.counters["statements"]
        adapter.executemany(f"insert into ct_many values ({ph}, {ph})",
                            [(i, float(i) / 4) for i in range(10)])
        assert adapter.counters["statements"] == before + 1
        rows = adapter.execute("select count(*), sum(v) from ct_many")
        assert int(rows[0][0]) == 10
        assert float(rows[0][1]) == pytest.approx(sum(i / 4
                                                      for i in range(10)))


# ---------------------------------------------------------------------------
# temp-table shadowing
# ---------------------------------------------------------------------------

class TestTempTables:
    def test_temp_shadows_main_for_this_connection_only(self, backend,
                                                        db_path):
        pool = ConnectionPool(backend, db_path, size=2)
        try:
            a, b = pool[0], pool[1]
            a.create_table("ct_shadow", [("v", "double precision")])
            a.bulk_insert("ct_shadow", [(1.0,)])
            a.commit()
            assert b.execute("select v from ct_shadow") == [(1.0,)]
            # the temp twin shadows the name on A only
            a.create_table("ct_shadow", [("v", "double precision")],
                           temp=True)
            a.bulk_insert("ct_shadow", [(2.0,)])
            assert a.execute("select v from ct_shadow") == [(2.0,)]
            assert b.execute("select v from ct_shadow") == [(1.0,)]
            # re-creating the MAIN table through the contract un-shadows
            # cleanly (the shim drops the temp twin first)
            a.create_table("ct_shadow", [("v", "double precision")])
            a.bulk_insert("ct_shadow", [(3.0,)])
            assert a.execute("select v from ct_shadow") == [(3.0,)]
        finally:
            pool.close()

    def test_memory_pool_is_independent_per_worker_sqlite(self, tmp_path):
        """:memory: sqlite pools are N independent databases — the shard
        trainer's temp-leaf ingestion covers this by writing every leaf
        per connection."""
        pool = ConnectionPool("sqlite", ":memory:", size=2)
        try:
            pool[0].create_table("only_here", [("v", "integer")])
            with pytest.raises(Exception):
                pool[1].execute("select * from only_here")
        finally:
            pool.close()


# ---------------------------------------------------------------------------
# concurrency
# ---------------------------------------------------------------------------

class TestConcurrentExecutemany:
    def test_threads_share_one_adapter_exactly(self, adapter):
        """N threads hammering ``bulk_insert`` on ONE adapter: the lock
        serializes raw access, the counters stay exact, every row lands."""
        adapter.create_table("ct_conc", [("t", "integer"),
                                         ("v", "double precision")])
        n_threads, per = 4, 200
        errs = []

        def work(t):
            try:
                adapter.bulk_insert(
                    "ct_conc", [(t, float(k)) for k in range(per)])
            except Exception as ex:  # pragma: no cover - the failure path
                errs.append(ex)

        threads = [threading.Thread(target=work, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errs
        rows = adapter.execute("select count(*) from ct_conc")
        assert int(rows[0][0]) == n_threads * per
        for t in range(n_threads):
            rows = adapter.execute(
                f"select count(*) from ct_conc where t = {adapter.placeholder}",
                (t,))
            assert int(rows[0][0]) == per


# ---------------------------------------------------------------------------
# generation registry (matrix-cache coherence)
# ---------------------------------------------------------------------------

class TestGenerationCounters:
    def test_sibling_write_flips_cache_stale(self, backend, db_path):
        pool = ConnectionPool(backend, db_path, size=2)
        try:
            a, b = pool[0], pool[1]
            m = RNG.randn(4, 3)
            relation_io.write_matrix(a, "ct_gen", m)
            a.commit()  # release the write txn before the sibling writes
            assert a.cache_fresh("ct_gen")
            relation_io.write_matrix(b, "ct_gen", m + 1)
            b.commit()
            assert not a.cache_fresh("ct_gen")
            assert b.cache_fresh("ct_gen")
        finally:
            pool.close()

    def test_temp_generations_key_per_adapter(self, backend, db_path):
        """A shard's temp-table churn must never invalidate a sibling's
        caches — temp generations live under a per-adapter key."""
        pool = ConnectionPool(backend, db_path, size=2)
        try:
            a, b = pool[0], pool[1]
            relation_io.write_matrix(b, "ct_tgen", RNG.randn(3, 3))
            b.commit()  # release the write txn — A writes only TEMP tables
            gen_b = b.table_gen("ct_tgen")
            assert b.cache_fresh("ct_tgen")
            for _ in range(3):  # A churns a TEMP table of the same name
                relation_io.write_matrix(a, "ct_tgen", RNG.randn(3, 3),
                                         temp=True)
            assert b.table_gen("ct_tgen") == gen_b
            assert b.cache_fresh("ct_tgen")
        finally:
            pool.close()


# ---------------------------------------------------------------------------
# differential: the engine runs correctly on every backend
# ---------------------------------------------------------------------------

class TestBackendDifferential:
    def _graph_env(self):
        spec = nn2sql.MLPSpec(n_rows=6, n_features=5, n_hidden=4,
                              n_classes=3, lr=0.05)
        g = nn2sql.build_graph(spec)
        env = {"img": RNG.randn(6, 5), "one_hot": np.eye(3)[RNG.randint(0, 3, 6)],
               "w_xh": RNG.randn(5, 4) * 0.3, "w_ho": RNG.randn(4, 3) * 0.3}
        return g, env

    def test_mlp_loss_and_grads_match_sqlite(self, backend, db_path):
        """The Algorithm-1 loss+gradient query, evaluated on the backend
        under test, against the sqlite baseline (itself pinned to the
        dense engine by tests/test_db_backend.py)."""
        g, env = self._graph_env()
        grads = autodiff.gradients(g.loss, [g.w_xh, g.w_ho])
        roots = [g.loss, grads[g.w_xh], grads[g.w_ho]]
        ref_eng = SQLEngine(plan_cache_=False)
        ref = ref_eng.evaluate(roots, env)
        eng = SQLEngine(adapter=connect(backend, db_path),
                        plan_cache_=False)
        try:
            got = eng.evaluate(roots, env)
            for r, o in zip(ref, got):
                np.testing.assert_allclose(o, r, atol=1e-9)
        finally:
            eng.close()
            ref_eng.close()

    def test_train_in_db_matches_sqlite(self, backend, db_path):
        """Three stepped training iterations end-to-end on the backend
        (the strategy every backend supports) vs the sqlite run."""
        from repro.db.train import train_in_db
        g, env = self._graph_env()
        w = {"w_xh": env["w_xh"], "w_ho": env["w_ho"]}
        ref = train_in_db(g, w, env["img"], env["one_hot"], 3,
                          strategy="stepped", plan_cache_=False)
        got = train_in_db(g, w, env["img"], env["one_hot"], 3,
                          backend=backend, path=db_path,
                          strategy="stepped", plan_cache_=False)
        for k in w:
            np.testing.assert_allclose(got.weights[k], ref.weights[k],
                                       atol=1e-9)
