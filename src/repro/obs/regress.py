"""Benchmark-metric normalisation and the perf-regression comparison.

Every ``BENCH_*.json`` carries (or, for files committed before this layer,
implies) a flat ``metrics`` block::

    "metrics": {
        "training.recursive_per_iter_s":
            {"value": 0.0489, "unit": "s", "direction": "lower"},
        "ingestion.pivot_speedup":
            {"value": 168.6, "unit": "x", "direction": "higher"},
        ...
    }

``direction`` says which way is better; an optional per-metric
``tolerance`` overrides the comparison-wide band.  :func:`compare` takes a
baseline and a fresh report, matches metrics by name, and flags a
regression only when the fresh value is worse by more than the tolerance
factor (default 1.5× — generous enough for CI-runner noise, tight enough
to catch a real slowdown).  ``benchmarks/check_regression.py`` drives it
and turns the result into a CI exit code plus a readable delta table.

:func:`metrics_from_report` is the single extraction point: it prefers the
embedded ``metrics`` block and falls back to deriving the headline numbers
from the known report shapes of the five committed benchmarks, so the gate
works against baselines that predate the block.
"""
from __future__ import annotations

import dataclasses


def metric(value, unit: str = "s", direction: str = "lower",
           tolerance: float | None = None) -> dict:
    """One normalised headline number (helper for the benchmark scripts)."""
    m = {"value": float(value), "unit": unit, "direction": direction}
    if tolerance is not None:
        m["tolerance"] = float(tolerance)
    return m


# ---------------------------------------------------------------------------
# extraction
# ---------------------------------------------------------------------------

def _legacy_metrics(report: dict) -> dict:
    """Headline metrics derived from pre-``metrics``-block report shapes
    (the committed baselines of PR 5-7)."""
    out: dict[str, dict] = {}

    def put(name, value, unit="s", direction="lower"):
        if isinstance(value, (int, float)) and value == value:
            out[name] = metric(value, unit, direction)

    ing = report.get("ingestion") or {}
    put("ingestion.pivot_speedup", ing.get("speedup"), "x", "higher")
    fg = report.get("forward_grad") or {}
    put("forward_grad.warm_s",
        fg.get("warm_s", fg.get("sqlite_warm_s")))
    put("forward_grad.cold_s",
        fg.get("cold_s", fg.get("sqlite_cold_s")))
    put("forward_grad.fused_speedup", fg.get("fused_speedup"), "x", "higher")
    trn = report.get("training") or {}
    put("training.recursive_per_iter_s", trn.get("recursive_per_iter_s"))
    trace = report.get("trace") or {}
    ti = trace.get("train_iteration") or trace
    put("trace.train_attribution", ti.get("attribution"), "frac", "higher")

    for r in report.get("results") or []:     # bench_array_vs_relational
        wl = r.get("workload")
        if not wl:
            continue
        put(f"{wl}.relational_s", r.get("relational_s"))
        put(f"{wl}.array_s", r.get("array_s"))
        put(f"{wl}.speedup_array", r.get("speedup_array"), "x", "higher")

    moe = report.get("moe") or {}             # bench_zoo_db
    put("moe.layer_sql_s", moe.get("layer_sql_s"))
    rwkv = report.get("rwkv") or {}
    put("rwkv.time_mix_sql_s", rwkv.get("time_mix_sql_s"))

    ssd = report.get("ssd") or {}             # bench_ssm_db
    put("ssd.relational_s", ssd.get("relational_s"))
    put("ssd.array_s", ssd.get("array_s"))
    lru = report.get("lru") or {}
    put("lru.relational_s", lru.get("relational_s"))
    put("lru.array_s", lru.get("array_s"))
    put("lru.grads_s", lru.get("grads_s"))
    return out


def metrics_from_report(report: dict) -> dict:
    """The normalised ``{name: {value, unit, direction, ...}}`` block of a
    benchmark report — embedded if present, derived for legacy shapes."""
    block = report.get("metrics")
    if isinstance(block, dict) and block:
        return {k: dict(v) for k, v in block.items()
                if isinstance(v, dict) and "value" in v}
    return _legacy_metrics(report)


# ---------------------------------------------------------------------------
# comparison
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Delta:
    """One metric's baseline-vs-fresh comparison."""

    name: str
    baseline: float | None
    fresh: float | None
    unit: str = "s"
    direction: str = "lower"
    ratio: float | None = None     # fresh / baseline
    tolerance: float = 1.5
    status: str = "ok"             # ok|improved|regressed|missing|new|skipped

    @property
    def failed(self) -> bool:
        return self.status in ("regressed", "missing")


def compare(baseline: dict, fresh: dict, tolerance: float = 1.5,
            gate_directions=("lower", "higher"),
            fail_on_missing: bool = True) -> list[Delta]:
    """Match two reports' metric blocks by name and judge each pair.

    A ``lower``-is-better metric regresses when ``fresh > baseline ×
    tolerance``; a ``higher``-is-better one when ``fresh < baseline /
    tolerance``.  Directions not in ``gate_directions`` are compared but
    never fail (``status="skipped"``) — the smoke gate times a reduced
    problem size, where absolute times only shrink but derived ratios
    (speedups) legitimately drop.  Fresh-only metrics report ``new``;
    baseline metrics the fresh run lost report ``missing`` (a deleted
    headline number is itself a regression unless ``fail_on_missing`` is
    off).  Per-metric ``tolerance`` keys override the global band."""
    base_m = metrics_from_report(baseline)
    fresh_m = metrics_from_report(fresh)
    deltas: list[Delta] = []
    for name in sorted(set(base_m) | set(fresh_m)):
        b, f = base_m.get(name), fresh_m.get(name)
        if b is None:
            deltas.append(Delta(name=name, baseline=None,
                                fresh=f["value"], unit=f.get("unit", "s"),
                                direction=f.get("direction", "lower"),
                                status="new"))
            continue
        direction = b.get("direction", "lower")
        unit = b.get("unit", "s")
        tol = float(b.get("tolerance", tolerance))
        if f is None:
            deltas.append(Delta(
                name=name, baseline=b["value"], fresh=None, unit=unit,
                direction=direction, tolerance=tol,
                status=("missing" if fail_on_missing
                        and direction in gate_directions else "skipped")))
            continue
        bv, fv = float(b["value"]), float(f["value"])
        ratio = (fv / bv) if bv else None
        d = Delta(name=name, baseline=bv, fresh=fv, unit=unit,
                  direction=direction, ratio=ratio, tolerance=tol)
        if direction not in gate_directions:
            d.status = "skipped"
        elif ratio is None:
            d.status = "ok"
        elif direction == "lower":
            d.status = ("regressed" if ratio > tol
                        else "improved" if ratio < 1.0 / tol else "ok")
        else:
            d.status = ("regressed" if ratio < 1.0 / tol
                        else "improved" if ratio > tol else "ok")
        deltas.append(d)
    return deltas


_MARK = {"ok": " ", "improved": "+", "regressed": "!",
         "missing": "!", "new": "·", "skipped": "~"}


def delta_table(deltas: list[Delta], title: str = "") -> str:
    """The readable comparison table CI prints and uploads."""
    width = max([len(d.name) for d in deltas] + [6])
    lines = []
    if title:
        lines.append(title)
    lines.append(f"  {'metric':<{width}} {'baseline':>12} {'fresh':>12} "
                 f"{'ratio':>7} {'status':>9}")

    def num(v):
        return "-" if v is None else f"{v:.6g}"

    for d in deltas:
        lines.append(
            f"{_MARK[d.status]} {d.name:<{width}} {num(d.baseline):>12} "
            f"{num(d.fresh):>12} "
            f"{('-' if d.ratio is None else f'{d.ratio:.2f}x'):>7} "
            f"{d.status:>9}")
    bad = [d for d in deltas if d.failed]
    lines.append(f"  {len(deltas)} metrics, "
                 f"{sum(1 for d in deltas if d.status == 'improved')} "
                 f"improved, {len(bad)} regressed"
                 + (f" ({', '.join(d.name for d in bad)})" if bad else ""))
    return "\n".join(lines)
