"""The "array data type" engine (paper Section 5).

The paper's second backend extends SQL arrays (``float[][]``) with matrix
algebra: ``**`` (matmul), ``*`` (Hadamard), ``-``, ``transpose``, ``sig`` and
elementwise aggregation. Here the array data type is simply a dense
``jnp.ndarray`` and the operations map 1:1 onto XLA ops; XLA's fusion pass
performs the "condensing of subsequent calls" that §6.3.2 plans as future
work for the database's query optimiser.

``eval_node`` is the single-node semantics shared with the relational
engine's fallback path (``core.rel_engine`` densifies, applies the same
rule, re-pivots) — one place defines what every zoo primitive means.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import expr as E
from .autodiff import MapDeriv, ReduceDeriv


def topk_mask(v: jnp.ndarray, k: int) -> jnp.ndarray:
    """The 0/1 indicator of each row's k largest entries, ties broken
    toward the smaller column index — byte-for-byte the ordering of the SQL
    lowering (``order by v desc, j asc``): rank(i, j) = #{m: v[i,m] >
    v[i,j]} + #{m < j: v[i,m] = v[i,j]}."""
    c = v.shape[1]
    gt = (v[:, None, :] > v[:, :, None]).sum(-1)            # (r, j) strict
    tri = jnp.tril(jnp.ones((c, c), dtype=bool), -1)        # m < j
    eq = ((v[:, None, :] == v[:, :, None]) & tri[None]).sum(-1)
    return ((gt + eq) < k).astype(v.dtype)


def row_shift(xv: jnp.ndarray, offset: int) -> jnp.ndarray:
    """out[t] = x[t - offset], zero fill (positive offset shifts down)."""
    t = xv.shape[0]
    if offset == 0:
        return xv
    out = jnp.zeros_like(xv)
    if abs(offset) >= t:
        return out
    if offset > 0:
        return out.at[offset:].set(xv[:-offset])
    return out.at[:offset].set(xv[-offset:])


def affine_scan(av: jnp.ndarray, bv: jnp.ndarray,
                reverse: bool) -> jnp.ndarray:
    """s_t = a_t ∘ s_{t∓1} + b_t down (or up) the rows, s outside = 0."""

    def step(s, ab):
        s2 = ab[0] * s + ab[1]
        return s2, s2

    _, outs = jax.lax.scan(step, jnp.zeros_like(av[0]), (av, bv),
                           reverse=reverse)
    return outs


def mat_affine_scan(av: jnp.ndarray, bv: jnp.ndarray, reverse: bool,
                    transposed: bool) -> jnp.ndarray:
    """s_t = s_{t∓1} · A_t + b_t with row-vector state; ``av`` is the
    (T·D, D) block stack, A_t = av[(t-1)D:tD] (transposed: A_tᵀ)."""
    t, d = bv.shape
    blocks = av.reshape(t, d, d)
    if transposed:
        blocks = jnp.swapaxes(blocks, 1, 2)

    def step(s, ab):
        s2 = s @ ab[0] + ab[1]
        return s2, s2

    _, outs = jax.lax.scan(step, jnp.zeros_like(bv[0]), (blocks, bv),
                           reverse=reverse)
    return outs


def _index_column(node: E.Expr, ev, n_rows: int) -> jnp.ndarray:
    """The (S,) int index column of a Gather/Scatter, bounds-checked when
    concrete.  Out-of-range indices are a contract violation the backends
    resolve differently in silence (jnp clamps gathers, the SQL join drops
    the tuple and the pivot zero-fills), so raise on every eager
    evaluation; under jit tracing the values are abstract and the check is
    skipped — behaviour there is backend-defined."""
    idx = ev(node.idx)[:, 0]
    if not isinstance(idx, jax.core.Tracer):
        lo, hi = int(jnp.min(idx)), int(jnp.max(idx))
        if idx.shape[0] and (lo < 0 or hi >= n_rows):
            raise ValueError(
                f"{type(node).__name__} index relation out of range: "
                f"values span [{lo}, {hi}], valid rows 0..{n_rows - 1}")
    return idx.astype(jnp.int32)


def eval_node(node: E.Expr, ev) -> jnp.ndarray:
    """One node's dense value; ``ev(child)`` supplies child values."""
    if isinstance(node, E.Const):
        return jnp.full(node.shape, node.value, dtype=jnp.float32)
    if isinstance(node, E.MatMul):
        return ev(node.x) @ ev(node.y)
    if isinstance(node, E.Hadamard):
        return ev(node.x) * ev(node.y)
    if isinstance(node, E.Add):
        return ev(node.x) + ev(node.y)
    if isinstance(node, E.Sub):
        return ev(node.x) - ev(node.y)
    if isinstance(node, E.Scale):
        return node.c * ev(node.x)
    if isinstance(node, E.Transpose):
        return ev(node.x).T
    if isinstance(node, MapDeriv):
        return node.fn.df(ev(node.x), ev(node.fx))
    if isinstance(node, ReduceDeriv):
        return (ev(node.x) == ev(node.red)).astype(jnp.float32)
    if isinstance(node, E.Map):
        return node.fn.fn(ev(node.x))
    if isinstance(node, E.RowReduce):
        red = jnp.sum if node.kind == "sum" else jnp.max
        return red(ev(node.x), axis=node.axis, keepdims=True)
    if isinstance(node, E.Softmax):
        return jax.nn.softmax(ev(node.x), axis=1)
    if isinstance(node, E.ArgTopK):
        return topk_mask(ev(node.x), node.k)
    if isinstance(node, E.Gather):
        return ev(node.x)[_index_column(node, ev, node.x.shape[0])]
    if isinstance(node, E.Scatter):
        return jax.ops.segment_sum(ev(node.x),
                                   _index_column(node, ev, node.shape[0]),
                                   num_segments=node.shape[0])
    if isinstance(node, E.RowShift):
        return row_shift(ev(node.x), node.offset)
    if isinstance(node, E.Recurrence):
        return affine_scan(ev(node.a), ev(node.b), node.reverse)
    if isinstance(node, E.MatRecurrence):
        return mat_affine_scan(ev(node.a), ev(node.b), node.reverse,
                               node.transposed)
    if isinstance(node, E.StepOuter):
        xv, yv = ev(node.x), ev(node.y)
        return (xv[:, :, None] * yv[:, None, :]).reshape(node.shape)
    raise TypeError(f"unknown node {type(node)}")


def evaluate(roots: list[E.Expr], env: dict[str, jnp.ndarray]) -> list[jnp.ndarray]:
    """Evaluate expression DAG(s) with per-node memoisation (CTE caching)."""
    cache: dict[int, jnp.ndarray] = {}

    def ev(node: E.Expr) -> jnp.ndarray:
        if id(node) in cache:
            return cache[id(node)]
        out = env[node.name] if isinstance(node, E.Var) else eval_node(node, ev)
        cache[id(node)] = out
        return out

    return [ev(r) for r in roots]
