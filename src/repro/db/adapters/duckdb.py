"""The duckdb backend — only when the ``duckdb`` package is importable.

Ingestion rides duckdb's native bulk path: zero-loop registration of the
column arrays (Arrow table when ``pyarrow`` is importable, pandas/numpy
dict otherwise) followed by one ``INSERT INTO … SELECT``."""
from __future__ import annotations

import os

from ...obs import tracer_of
from ..dialect import HAVE_DUCKDB, DuckDBDialect, duckdb
from .base import Adapter


class DuckDBAdapter(Adapter):
    placeholder = "?"

    def __init__(self, path: str = ":memory:"):
        if not HAVE_DUCKDB:  # pragma: no cover - depends on environment
            raise ImportError("duckdb is not installed; "
                              "use backend='sqlite' or pip install repro[db]")
        self.dialect = DuckDBDialect()
        super().__init__(duckdb.connect(path))
        if path != ":memory:":  # pragma: no cover - needs duckdb
            self._db_key = "duckdb:" + os.path.abspath(path)

    def cursor_adapter(self) -> "DuckDBAdapter":  # pragma: no cover - duckdb
        """A pool worker over this connection: ``conn.cursor()`` is a full
        DuckDBPyConnection sharing the root's catalog, with its own temp
        namespace and transaction state — duckdb's one-writer model with
        per-worker cursors.  The worker shares ``_db_key`` (same logical
        database) but carries its own lock and caches.
        """
        # obs: exempt — pool-worker construction, not a query; every
        # statement the worker runs goes through the traced base methods
        other = object.__new__(DuckDBAdapter)
        other.dialect = DuckDBDialect()
        Adapter.__init__(other, self.conn.cursor())
        other._db_key = self._db_key
        return other

    def executemany(self, sql, rows):  # pragma: no cover - needs duckdb
        # tuple-normalise for duckdb's binder, then ride the traced base
        Adapter.executemany(self, sql, [tuple(r) for r in rows])

    def explain_sql(self, sql: str) -> str:  # pragma: no cover - needs duckdb
        """duckdb spells it plain ``EXPLAIN`` (physical plan as text)."""
        try:
            rows = self.execute("explain " + sql)
        except Exception:
            return ""
        return "\n".join(str(r[-1]) for r in rows)

    def insert_columns(self, name, cols):  # pragma: no cover - needs duckdb
        """Register the column arrays as a relation (Arrow when available,
        else a pandas DataFrame built zero-copy from the ndarrays) and run
        ONE ``INSERT INTO … SELECT`` — duckdb's native bulk path; no
        per-row Python at all."""
        cols, n = self._prepare_columns(name, cols)
        if not n:
            return
        names = [f"c{k}" for k in range(len(cols))]
        view = f"_ingest_{name}"
        frame = None
        try:
            import pyarrow as pa
            frame = pa.table({nm: pa.array(c) for nm, c in zip(names, cols)})
        except ImportError:
            try:
                import pandas as pd
                frame = pd.DataFrame(dict(zip(names, cols)))
            except ImportError:
                pass
        if frame is None:  # no columnar frontend — generic chunked path
            Adapter.insert_columns(self, name, cols)
            return
        tr = tracer_of(self)
        with tr.span("db.ingest_register", table=name, rows=n):
            self.conn.register(view, frame)
            try:
                self.execute(f"insert into {name} select * from {view}")
            finally:
                self.conn.unregister(view)
