"""Production training launcher.

On a real fleet each host runs this with its process index; here it runs
the same code path single-host. ``--dry-run-mesh`` routes through the
512-device placeholder mesh (see dryrun.py for the pure-AOT variant).

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --reduced \
        --steps 50 --seq 128 --batch 8
"""
import argparse

import jax

from repro.configs.base import get_config
from repro.data import TokenPipeline, stub_frontend_batch
from repro.nn.model import LM
from repro.optim import adamw
from repro.train import Trainer


class StubPipeline:
    """Frontend-stub data source ([audio]/[vlm] archs)."""

    def __init__(self, cfg, seq_len, global_batch):
        self.cfg, self.seq, self.batch = cfg, seq_len, global_batch

    def batch_at(self, step: int):
        return stub_frontend_batch(self.cfg.stub_frontend, self.batch,
                                   self.seq, self.cfg.d_model,
                                   self.cfg.vocab, seed=step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    lm = LM(cfg)
    if cfg.stub_frontend:
        data = StubPipeline(cfg, args.seq, args.batch)
    else:
        data = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch)
    trainer = Trainer(lm, adamw(args.lr), data,
                      checkpoint_dir=args.ckpt_dir,
                      grad_accum=args.grad_accum)
    out = trainer.run(jax.random.PRNGKey(0), args.steps, log_every=10)
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.4f} → {h[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
