"""DeepSeek-V2-Lite (16B total) — MLA (kv_lora 512) + fine-grained MoE:
64 routed experts top-6 + 2 shared, leading dense FFN layer
[arXiv:2405.04434; hf]. The paper technique applies in full here: the
token→expert relation executes as join + group-by (nn/moe.py)."""
from .base import ArchConfig, MLAConfig, MoESpec

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, d_head=128, d_ff=1408, vocab=102400,
    mla=MLAConfig(kv_lora=512, d_nope=128, d_rope=64, d_v=128),
    moe=MoESpec(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                first_k_dense=1, d_ff_dense=10944, router_softmax="pre"),
    rope_theta=1e4)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-reduced", family="moe", n_layers=3,
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=64, vocab=256,
        mla=MLAConfig(kv_lora=32, d_nope=16, d_rope=8, d_v=16),
        moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
                    first_k_dense=1, d_ff_dense=128, router_softmax="pre"))
