"""State-space models in the database (repro.db.zoo.ssm_to_sql).

Ground truth is ``nn/ssm.ssd_naive`` (the step-by-step SSD oracle the
Mamba-2 kernels are validated against):

* the kron-flattened SSD scan — full-sequence AND chunked execution —
  reproduces ssd_naive's outputs and final state ≤1e-4 in both
  representations;
* Algorithm-1 gradients of the in-DB SSD graph match jax.grad through
  ssd_naive;
* the LRU layer (dense-block MatRecurrence and the diagonal fast path)
  matches its scan oracle forward, and its in-DB gradients match
  jax.grad — including the stacked ∂A blocks;
* duckdb (CI extras job): the same differentials on a real duckdb
  connection.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dense
from repro.core import expr as E
from repro.core.autodiff import gradients
from repro.db import HAVE_DUCKDB, zoo
from repro.db.sql_engine import SQLEngine
from repro.nn import ssm

TOL = 1e-4
RNG = np.random.RandomState(5)

S, N, P = 6, 3, 2
XV = RNG.randn(S, P).astype(np.float32)
AV = (-RNG.rand(S).astype(np.float32))        # log decay ≤ 0
BV = (RNG.randn(S, N) * 0.5).astype(np.float32)
CV = (RNG.randn(S, N) * 0.5).astype(np.float32)


def ssd_naive_single():
    """nn/ssm.ssd_naive at B=H=1, unwrapped to (S, P) / (N, P)."""
    y, h = ssm.ssd_naive(jnp.asarray(XV[None, :, None, :]),
                         jnp.asarray(AV[None, :, None]),
                         jnp.asarray(BV[None]), jnp.asarray(CV[None]))
    return np.asarray(y)[0, :, 0, :], np.asarray(h)[0, 0]


class TestSSD:
    def test_numpy_twin_matches_ssd_naive(self):
        y_ref, h_ref = ssd_naive_single()
        y, h = zoo.ssd_ref(XV, AV, BV, CV)
        np.testing.assert_allclose(y, y_ref, atol=1e-5)
        np.testing.assert_allclose(h, h_ref, atol=1e-5)

    @pytest.mark.parametrize("dialect", [None, "array"])
    def test_in_db_matches_ssd_naive(self, dialect):
        y_ref, h_ref = ssd_naive_single()
        with SQLEngine(dialect=dialect, plan_cache_=False) as eng:
            y, h = zoo.run_ssd_in_db(XV, AV, BV, CV, engine=eng)
        np.testing.assert_allclose(y, y_ref, atol=TOL)
        np.testing.assert_allclose(h, h_ref, atol=TOL)

    @pytest.mark.parametrize("chunk", [1, 2, 4])
    def test_chunked_equals_full(self, chunk):
        """The Mamba-2-style chunked execution: chunk-final states carried
        through the h0 leaf reproduce the monolithic scan exactly."""
        y_ref, h_ref = ssd_naive_single()
        with SQLEngine(plan_cache_=False) as eng:
            y, h = zoo.run_ssd_in_db(XV, AV, BV, CV, chunk=chunk,
                                     engine=eng)
        np.testing.assert_allclose(y, y_ref, atol=TOL)
        np.testing.assert_allclose(h, h_ref, atol=TOL)

    def test_nonzero_initial_state(self):
        h0 = (RNG.randn(N, P) * 0.5).astype(np.float32)
        y_ref, h_ref = zoo.ssd_ref(XV, AV, BV, CV, h0)
        with SQLEngine(plan_cache_=False) as eng:
            y, h = zoo.run_ssd_in_db(XV, AV, BV, CV, h0, engine=eng)
        np.testing.assert_allclose(y, y_ref, atol=TOL)
        np.testing.assert_allclose(h, h_ref, atol=TOL)

    def test_gradients_match_jax_through_ssd_naive(self):
        """Algorithm 1 on the in-DB graph vs jax.grad of the ssd_naive
        loss Σ y² — the reverse-scan VJP through the kron flattening."""
        graph = zoo.ssd_scan_graph(S, N, P)
        xt, bt, ct = graph.leaves[0], graph.leaves[1], graph.leaves[2]
        loss = E.square(graph.y)
        g = gradients(loss, [xt, bt, ct])
        env = zoo.ssd_env(XV, AV, BV, CV)
        roots = [g[xt], g[bt], g[ct]]

        def f(x, b, c):
            y, _ = ssm.ssd_naive(x[None, :, None, :],
                                 jnp.asarray(AV[None, :, None]),
                                 b[None], c[None])
            return jnp.sum(y ** 2)

        oracle = jax.grad(f, argnums=(0, 1, 2))(
            jnp.asarray(XV), jnp.asarray(BV), jnp.asarray(CV))
        with SQLEngine(plan_cache_=False) as eng:
            got = eng.evaluate(roots, env)
        for s, j in zip(got, oracle):
            np.testing.assert_allclose(s, np.asarray(j), atol=TOL)
        with SQLEngine(dialect="array", plan_cache_=False) as eng:
            got_arr = eng.evaluate(roots, env)
        for s, j in zip(got_arr, oracle):
            np.testing.assert_allclose(s, np.asarray(j), atol=TOL)


class TestLRU:
    D_IN, D, D_OUT = 3, 4, 2
    U = RNG.randn(S, D_IN).astype(np.float32)
    A = (RNG.randn(D, D) * 0.3).astype(np.float32)
    LAM = (RNG.rand(D) * 0.8).astype(np.float32)
    WB = (RNG.randn(D_IN, D) * 0.5).astype(np.float32)
    WC = (RNG.randn(D, D_OUT) * 0.5).astype(np.float32)

    def a(self, diagonal):
        return self.LAM if diagonal else self.A

    def jax_loss(self, diagonal):
        def f(u, a, wb, wc):
            b = u @ wb
            def step(h, bt):
                h2 = (h * a if diagonal else h @ a) + bt
                return h2, h2
            _, hs = jax.lax.scan(step, jnp.zeros(self.D), b)
            return jnp.sum((hs @ wc) ** 2)
        return f

    @pytest.mark.parametrize("diagonal", [False, True])
    @pytest.mark.parametrize("dialect", [None, "array"])
    def test_forward(self, diagonal, dialect):
        y_ref, _ = zoo.lru_ref(self.U, self.a(diagonal), self.WB, self.WC,
                               diagonal=diagonal)
        with SQLEngine(dialect=dialect, plan_cache_=False) as eng:
            y = zoo.run_lru_in_db(self.U, self.a(diagonal), self.WB,
                                  self.WC, diagonal=diagonal, engine=eng)
        np.testing.assert_allclose(y, y_ref, atol=TOL)

    @pytest.mark.parametrize("diagonal", [False, True])
    def test_gradients_match_jax(self, diagonal):
        a = self.a(diagonal)
        with SQLEngine(plan_cache_=False) as eng:
            loss, grads = zoo.lru_grads_in_db(self.U, a, self.WB, self.WC,
                                              diagonal=diagonal, engine=eng)
        oracle = jax.grad(self.jax_loss(diagonal), argnums=(0, 1, 2, 3))(
            jnp.asarray(self.U), jnp.asarray(a), jnp.asarray(self.WB),
            jnp.asarray(self.WC))
        np.testing.assert_allclose(grads["u"], np.asarray(oracle[0]),
                                   atol=TOL)
        got_a = (grads["lam"].reshape(-1) if diagonal
                 else grads["a_stack"].reshape(S, self.D, self.D).sum(0))
        np.testing.assert_allclose(got_a, np.asarray(oracle[1]), atol=TOL)
        np.testing.assert_allclose(grads["wb"], np.asarray(oracle[2]),
                                   atol=TOL)
        np.testing.assert_allclose(grads["wc"], np.asarray(oracle[3]),
                                   atol=TOL)

    def test_dense_block_grads_execute_in_array_dialect(self):
        with SQLEngine(dialect="array", plan_cache_=False) as eng:
            loss, grads = zoo.lru_grads_in_db(self.U, self.A, self.WB,
                                              self.WC, engine=eng)
        oracle = jax.grad(self.jax_loss(False), argnums=(1,))(
            jnp.asarray(self.U), jnp.asarray(self.A), jnp.asarray(self.WB),
            jnp.asarray(self.WC))
        np.testing.assert_allclose(
            grads["a_stack"].reshape(S, self.D, self.D).sum(0),
            np.asarray(oracle[0]), atol=TOL)


@pytest.mark.skipif(not HAVE_DUCKDB, reason="duckdb not installed")
class TestDuckDB:
    """CI duckdb-extras: the SSM workloads on a real duckdb connection —
    the array-representation scans run with no Python aggregate."""

    @pytest.mark.parametrize("dialect", [None, "array"])
    def test_ssd(self, dialect):
        y_ref, h_ref = ssd_naive_single()
        with SQLEngine(backend="duckdb", dialect=dialect,
                       plan_cache_=False) as eng:
            y, h = zoo.run_ssd_in_db(XV, AV, BV, CV, engine=eng)
        np.testing.assert_allclose(y, y_ref, atol=TOL)
        np.testing.assert_allclose(h, h_ref, atol=TOL)

    @pytest.mark.parametrize("dialect", [None, "array"])
    def test_lru_dense_block(self, dialect):
        t = TestLRU
        y_ref, _ = zoo.lru_ref(t.U, t.A, t.WB, t.WC)
        with SQLEngine(backend="duckdb", dialect=dialect,
                       plan_cache_=False) as eng:
            y = zoo.run_lru_in_db(t.U, t.A, t.WB, t.WC, engine=eng)
        np.testing.assert_allclose(y, y_ref, atol=TOL)
