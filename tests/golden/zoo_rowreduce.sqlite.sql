with recursive rsum_c0(i, j, v) as (
  select i, 1 as j, sum(v) as v from zx
  group by i
),
rmax_c1(i, j, v) as (
  select 1 as i, j, max(v) as v from zx
  group by j
)
select 0 as r, i, j, v from rsum_c0
union all select 1 as r, i, j, v from rmax_c1;
